//! Malformed-input robustness of the serving wire: the parser and the
//! TCP loop must turn hostile bytes into errors, never into panics —
//! a panic in a connection thread (or a stack-overflow abort in the
//! parser) is a one-request denial of service against the always-on
//! coordinator. Companion to the `panic-freedom` lint rule, which proves
//! the same property statically.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use pdpu::coordinator::{json, Metrics, Server, ServerPolicy, ServiceHandle};
use pdpu::pdpu::PdpuConfig;

/// Every prefix of a valid request — i.e. every possible truncation
/// point of a line cut mid-flight — parses to a clean `Err`, not a panic.
#[test]
fn truncated_json_errors_not_panics() {
    let full = r#"{"op":"train","images":[[0.5,-1.0],[2.0,0.0]],"labels":[1,0],"note":"trunc é"}"#;
    assert!(json::parse(full).is_ok());
    for cut in 0..full.len() {
        if !full.is_char_boundary(cut) {
            continue;
        }
        let prefix = &full[..cut];
        assert!(json::parse(prefix).is_err(), "truncated prefix {cut:?} ({prefix:?}) must be an error");
    }
}

/// Unbalanced/garbage payloads all error out cleanly.
#[test]
fn garbage_payloads_error_not_panic() {
    for bad in [
        "",
        "   ",
        "not json at all",
        "{",
        "}",
        "[1,2",
        "{\"op\":}",
        "{\"op\" \"ping\"}",
        "\"unterminated",
        "123abc",
        "{\"op\":\"ping\"} trailing",
        "\u{0}\u{1}\u{2}",
    ] {
        assert!(json::parse(bad).is_err(), "{bad:?} must be a parse error");
    }
}

/// Deeply-nested input is rejected by the depth guard instead of
/// overflowing the parser's stack (recursive descent would otherwise
/// abort the whole process — no unwinding, no error response).
#[test]
fn nesting_bombs_are_rejected_not_fatal() {
    let unclosed_arrays = "[".repeat(100_000);
    let unclosed_objects = "{\"a\":".repeat(100_000);
    let balanced = format!("{}1{}", "[".repeat(100_000), "]".repeat(100_000));
    for bomb in [&unclosed_arrays, &unclosed_objects, &balanced] {
        let e = json::parse(bomb).unwrap_err();
        assert!(e.contains("nesting"), "depth guard should reject the bomb: {e}");
    }
}

fn start_test_server() -> (Server, ServiceHandle, Arc<Metrics>) {
    let svc = ServiceHandle::start_software(PdpuConfig::paper_default(), vec![6, 3], 4, (2, 2, 2), 0xD05).unwrap();
    let metrics = Arc::new(Metrics::new());
    let server = Server::start("127.0.0.1:0", svc.clone(), metrics.clone()).expect("bind test server");
    (server, svc, metrics)
}

fn start_policy_server(policy: ServerPolicy) -> (Server, ServiceHandle, Arc<Metrics>) {
    let svc = ServiceHandle::start_software(PdpuConfig::paper_default(), vec![6, 3], 4, (2, 2, 2), 0xD05).unwrap();
    let metrics = Arc::new(Metrics::new());
    let server =
        Server::start_with("127.0.0.1:0", svc.clone(), metrics.clone(), policy).expect("bind test server");
    (server, svc, metrics)
}

fn ping_ok(addr: std::net::SocketAddr) -> bool {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"op\":\"ping\"}\n").expect("send ping");
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("read pong");
    let v = json::parse(&resp).expect("pong is json");
    v.get("pong").is_some()
}

/// A connection feeding garbage, truncated JSON, and a nesting bomb gets
/// an error *response* per line — and the server keeps serving pings on
/// fresh connections afterwards.
#[test]
fn hostile_lines_get_error_responses_and_server_survives() {
    let (server, _svc, _metrics) = start_test_server();
    let stream = TcpStream::connect(server.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    let hostile = ["not json at all", "{\"op\":\"inf", "{\"op\":\"no-such-op\"}", "{\"op\":\"infer\"}"];
    for line in hostile {
        writer.write_all(line.as_bytes()).expect("send");
        writer.write_all(b"\n").expect("send newline");
        let mut resp = String::new();
        reader.read_line(&mut resp).expect("read response");
        let v = json::parse(&resp).unwrap_or_else(|e| panic!("response to {line:?} not json: {e} ({resp:?})"));
        assert!(v.get("error").is_some(), "hostile line {line:?} must get an error response: {resp:?}");
    }
    // a nesting bomb on the wire gets the depth-guard error, not an abort
    let bomb = format!("{}\n", "[".repeat(50_000));
    writer.write_all(bomb.as_bytes()).expect("send bomb");
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("read bomb response");
    assert!(resp.contains("nesting"), "bomb should be rejected by the depth guard: {resp:?}");

    assert!(ping_ok(server.addr), "server must still serve after hostile traffic");
}

/// Raw non-UTF-8 bytes make `BufRead::lines` error; the connection drops
/// without a response — but only that connection. The server survives.
#[test]
fn non_utf8_bytes_drop_the_connection_not_the_server() {
    let (server, _svc, _metrics) = start_test_server();
    let stream = TcpStream::connect(server.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writer.write_all(&[0xFF, 0xFE, 0x80, 0x00, 0xC3, 0x28, b'\n']).expect("send raw bytes");
    // the server closes this connection (read returns 0 bytes eventually)
    let mut buf = [0u8; 64];
    let n = reader.read(&mut buf).unwrap_or(0);
    assert_eq!(n, 0, "non-UTF-8 line should close the connection silently");

    assert!(ping_ok(server.addr), "server must still serve after a non-UTF-8 connection");
}

/// A line longer than `max_line_bytes` gets a bounded-reader error reply
/// and the connection is closed — the server never buffers the whole
/// line, so a newline-free byte stream can no longer grow memory without
/// bound. The rejection is also *counted*.
#[test]
fn oversized_request_line_is_rejected_and_counted() {
    let policy = ServerPolicy { max_line_bytes: 1024, ..ServerPolicy::default() };
    let (server, _svc, metrics) = start_policy_server(policy);
    let stream = TcpStream::connect(server.addr).expect("connect");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);

    // two-phase write: exactly the cap first (still legal), then push it
    // over — the server has consumed phase one by the time it rejects, so
    // the error reply isn't lost to a reset-on-close race
    writer.write_all(&vec![b'x'; 1024]).expect("send cap bytes");
    writer.flush().expect("flush");
    std::thread::sleep(std::time::Duration::from_millis(100));
    writer.write_all(&vec![b'x'; 200]).expect("send overflow bytes");

    let mut resp = String::new();
    reader.read_line(&mut resp).expect("read rejection");
    let v = json::parse(&resp).expect("rejection is json");
    assert_eq!(v.get("ok"), Some(&json::Json::Bool(false)), "{resp:?}");
    let msg = v.get("error").and_then(json::Json::as_str).expect("error field");
    assert!(msg.contains("exceeds"), "unexpected rejection message: {msg}");
    // the connection is closed after the reply
    let mut buf = [0u8; 16];
    assert_eq!(reader.read(&mut buf).unwrap_or(0), 0, "connection should close after an oversized line");

    assert!(ping_ok(server.addr), "server must still serve after an oversized line");
    let s = metrics.snapshot();
    assert!(s.requests >= 1, "oversized line must count as a request");
    assert!(s.errors >= 1, "oversized line must count as an error");
}

/// An idle connection (bytes may come later) does not wedge its shard:
/// other clients keep getting served, and the idle connection still works
/// once it finally speaks.
#[test]
fn idle_connection_does_not_block_service() {
    let (server, _svc, _metrics) = start_test_server();
    let stream = TcpStream::connect(server.addr).expect("connect idle");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    std::thread::sleep(std::time::Duration::from_millis(150));

    // fresh connections are served while the first one sits idle
    assert!(ping_ok(server.addr), "idle connection must not block new clients");

    // and the idle connection itself is still alive
    writer.write_all(b"{\"op\":\"ping\"}\n").expect("send late ping");
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("read late pong");
    assert!(json::parse(&resp).expect("pong json").get("pong").is_some(), "{resp:?}");
}

/// Rapid connect/disconnect churn — including sockets dropped before the
/// server ever reads a byte — leaves every accept loop alive.
#[test]
fn server_survives_connection_churn() {
    let (server, _svc, _metrics) = start_test_server();
    let addr = server.addr;
    let mut handles = Vec::new();
    for _ in 0..4 {
        handles.push(std::thread::spawn(move || {
            for _ in 0..50 {
                drop(TcpStream::connect(addr).expect("churn connect"));
            }
        }));
    }
    for h in handles {
        h.join().expect("churn thread");
    }
    assert!(ping_ok(addr), "server must still accept after connection churn");
}

/// Saturating a one-permit admission budget sheds with the structured
/// `{"ok":false,"shed":true}` reply, the shed counter matches what
/// clients observed, and every request is accounted for.
#[test]
fn saturated_admission_budget_sheds_structurally() {
    let policy = ServerPolicy { shards: 1, max_inflight: 1, ..ServerPolicy::default() };
    let (server, _svc, metrics) = start_policy_server(policy);
    let addr = server.addr;

    const THREADS: usize = 6;
    const PER_THREAD: usize = 40;
    let mut handles = Vec::new();
    for _ in 0..THREADS {
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).expect("connect");
            let mut writer = stream.try_clone().expect("clone");
            let mut reader = BufReader::new(stream);
            let mut sheds = 0u64;
            // valid 2x2 gemm payload for the (2, 2, 2) test service
            let req = "{\"op\":\"gemm\",\"a\":[1,0,0,1],\"b\":[0.5,0,0,0.5]}\n";
            for _ in 0..PER_THREAD {
                writer.write_all(req.as_bytes()).expect("send gemm");
                let mut resp = String::new();
                reader.read_line(&mut resp).expect("read gemm reply");
                let v = json::parse(&resp).expect("reply is json");
                match v.get("ok") {
                    Some(json::Json::Bool(true)) => {}
                    Some(json::Json::Bool(false)) => {
                        assert_eq!(
                            v.get("shed"),
                            Some(&json::Json::Bool(true)),
                            "only sheds may fail under saturation: {resp:?}"
                        );
                        sheds += 1;
                    }
                    other => panic!("malformed reply {other:?}: {resp:?}"),
                }
            }
            sheds
        }));
    }
    let observed_sheds: u64 = handles.into_iter().map(|h| h.join().expect("client thread")).sum();
    assert!(observed_sheds > 0, "a one-permit budget under 6 hammering clients must shed");

    let s = metrics.snapshot();
    let total = (THREADS * PER_THREAD) as u64;
    assert_eq!(s.shed_requests, observed_sheds, "shed counter must match client-observed sheds");
    assert_eq!(s.requests, total, "shed requests still count as requests");
    assert_eq!(s.responses, total - observed_sheds, "every admitted request got a response");
    assert_eq!(s.errors, 0, "sheds are not errors");

    // the stats wire op surfaces the new fields
    let stream = TcpStream::connect(addr).expect("connect stats");
    let mut writer = stream.try_clone().expect("clone");
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"op\":\"stats\"}\n").expect("send stats");
    let mut resp = String::new();
    reader.read_line(&mut resp).expect("read stats");
    let v = json::parse(&resp).expect("stats json");
    let field = |k: &str| v.get(k).and_then(json::Json::as_f64).unwrap_or_else(|| panic!("missing {k}: {resp:?}"));
    assert_eq!(field("shed_requests"), observed_sheds as f64);
    assert_eq!(field("shards"), 1.0);
    assert!(field("accept_retries") >= 0.0);
    assert!(field("plane_cache_misses") >= 1.0, "fused gemms go through the plane cache");
}
