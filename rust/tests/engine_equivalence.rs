//! Property tests for the batched GEMM engine and the fused serving path:
//! across random PDPU configurations (uniform and mixed precision,
//! N ∈ {1,4,8}, Wm ∈ 6..=96), `dot_batch`/`gemm` must be
//! **bit-identical** to the scalar `dot_f64`/`dot_chunked` loop, and
//! invariant to the worker thread count and the column-block (tile)
//! width; cross-request fusion (`coordinator::fusion`) must be
//! bit-identical to one-launch-per-request execution, never fuse across
//! configs, and never reorder responses. This is the acceptance invariant
//! of the whole execution stack: batching, tiling, and fusion are
//! scheduling optimizations, never a numerics change.

use pdpu::baselines::{DotArch, IeeeArith, MulAddTreeDpu, PdpuArch, QuirePdpuArch};
use pdpu::baselines::{FmaCascadeDpu, IeeeFormat, PositArith};
use pdpu::coordinator::fusion::{execute_fused, execute_unfused, plan_fusion, GemmTile};
use pdpu::engine::{BatchEngine, PreparedOperands};
use pdpu::pdpu::{Pdpu, PdpuConfig};
use pdpu::posit::{Posit, PositFormat};
use pdpu::testing::diff::random_config;
use pdpu::testing::Rng;

/// The scalar reference for one output element: quantize and run
/// `dot_chunked`, exactly as `PdpuArch::dot_f64` does.
fn scalar_dot(cfg: &PdpuConfig, acc: f64, a: &[f64], b: &[f64]) -> f64 {
    let unit = Pdpu::new(*cfg);
    let qa: Vec<Posit> = a.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
    let qb: Vec<Posit> = b.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
    unit.dot_chunked(Posit::from_f64(acc, cfg.out_fmt), &qa, &qb).to_f64()
}

#[test]
fn dot_batch_bit_identical_to_scalar_dot_chunked_across_configs() {
    let mut rng = Rng::seeded(0xB17_E4AC);
    for round in 0..60 {
        let cfg = random_config(&mut rng);
        let arch = PdpuArch::new(cfg);
        let rows = 1 + rng.below(5) as usize;
        let cols = 1 + rng.below(5) as usize;
        // k intentionally often not a multiple of N: exercises the padded tail
        let k = 1 + rng.below(40) as usize;
        let w: Vec<f64> = (0..rows * k).map(|_| rng.log_uniform_signed(-8.0, 8.0)).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.log_uniform_signed(-8.0, 8.0)).collect();
        let acc: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let got = arch.dot_batch(&acc, &w, &x, k);
        assert_eq!(got.len(), rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                let want = scalar_dot(&cfg, acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(
                    got[r * cols + c].to_bits(),
                    want.to_bits(),
                    "round {round} cfg {} out[{r},{c}]: got {} want {want}",
                    cfg.label(),
                    got[r * cols + c]
                );
                // and the trait's scalar entry point agrees too
                let via_dot_f64 = arch.dot_f64(acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(got[r * cols + c].to_bits(), via_dot_f64.to_bits());
            }
        }
    }
}

#[test]
fn gemm_invariant_to_worker_thread_count() {
    let mut rng = Rng::seeded(0x7764D);
    for _ in 0..12 {
        let cfg = random_config(&mut rng);
        let (rows, cols, k) = (
            1 + rng.below(12) as usize,
            1 + rng.below(9) as usize,
            1 + rng.below(50) as usize,
        );
        let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
        let acc: Vec<f64> = vec![0.0; rows];
        let baseline = BatchEngine::new(cfg).with_threads(1).gemm_f64(&acc, &w, &x, k);
        for threads in [2usize, 3, 7, 32] {
            let got = BatchEngine::new(cfg).with_threads(threads).gemm_f64(&acc, &w, &x, k);
            assert_eq!(
                baseline.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "cfg {} threads {threads}",
                cfg.label()
            );
        }
    }
}

#[test]
fn gemm_invariant_to_col_block_width() {
    let mut rng = Rng::seeded(0xC01B10C);
    for _ in 0..12 {
        let cfg = random_config(&mut rng);
        let (rows, cols, k) = (
            1 + rng.below(8) as usize,
            1 + rng.below(20) as usize,
            1 + rng.below(40) as usize,
        );
        let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
        let acc: Vec<f64> = vec![0.0; rows];
        let baseline = BatchEngine::new(cfg).with_threads(1).with_col_block(1).gemm_f64(&acc, &w, &x, k);
        for col_block in [0usize, 2, 3, 7, 128] {
            for threads in [1usize, 4] {
                let got = BatchEngine::new(cfg)
                    .with_threads(threads)
                    .with_col_block(col_block)
                    .gemm_f64(&acc, &w, &x, k);
                assert_eq!(
                    baseline.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "cfg {} col_block {col_block} threads {threads}",
                    cfg.label()
                );
            }
        }
    }
}

/// Random request queue over at most `planes` distinct shared left
/// operand planes: the serving shape cross-request fusion targets.
fn random_queue(rng: &mut Rng, cfg: PdpuConfig, planes: usize, tiles: usize) -> Vec<GemmTile> {
    let m = 1 + rng.below(4) as usize;
    let k = 1 + rng.below(24) as usize;
    let shared: Vec<(Vec<f64>, Vec<f64>)> = (0..planes)
        .map(|_| {
            (
                (0..m).map(|_| rng.normal()).collect(),
                (0..m * k).map(|_| rng.normal()).collect(),
            )
        })
        .collect();
    (0..tiles)
        .map(|_| {
            let (acc, a) = shared[rng.below(planes as u64) as usize].clone();
            let n = 1 + rng.below(5) as usize;
            let bt: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            GemmTile { cfg, k, acc, a, bt }
        })
        .collect()
}

#[test]
fn fused_cross_request_launch_bit_identical_to_unfused() {
    let mut rng = Rng::seeded(0xF05E_D0E5);
    for round in 0..25 {
        let cfg = random_config(&mut rng);
        let planes = 1 + rng.below(3) as usize;
        let tiles = 1 + rng.below(8) as usize;
        let queue = random_queue(&mut rng, cfg, planes, tiles);
        let (fused, stats) = execute_fused(&queue);
        let unfused = execute_unfused(&queue);
        assert_eq!(fused.len(), queue.len());
        assert!(stats.launches as usize <= queue.len());
        for (i, (f, u)) in fused.iter().zip(&unfused).enumerate() {
            assert_eq!(
                f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                u.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "round {round} cfg {} tile {i}",
                cfg.label()
            );
        }
    }
}

#[test]
fn fusion_preserves_response_order_against_scalar_oracle() {
    // every fused response must be its own tile's result — checked not
    // against the engine but against the scalar dot_chunked oracle, so a
    // response swap between look-alike tiles cannot go unnoticed
    let mut rng = Rng::seeded(0x0D0_0E4);
    for _ in 0..10 {
        let cfg = random_config(&mut rng);
        let queue = random_queue(&mut rng, cfg, 2, 6);
        let (fused, _) = execute_fused(&queue);
        for (t, out) in queue.iter().zip(&fused) {
            let (m, n) = (t.m(), t.n());
            for r in 0..m {
                for c in 0..n {
                    let want = scalar_dot(
                        &cfg,
                        t.acc[r],
                        &t.a[r * t.k..(r + 1) * t.k],
                        &t.bt[c * t.k..(c + 1) * t.k],
                    );
                    assert_eq!(out[r * n + c].to_bits(), want.to_bits(), "cfg {}", cfg.label());
                }
            }
        }
    }
}

#[test]
fn mixed_config_queues_never_fuse() {
    // identical operand planes but differing PdpuConfigs: the plan must
    // keep every tile in its own launch (a fused launch would execute the
    // wrong datapath for one of them)
    let mut rng = Rng::seeded(0x3113);
    for _ in 0..20 {
        let cfg_a = random_config(&mut rng);
        let cfg_b = random_config(&mut rng);
        if cfg_a == cfg_b {
            continue;
        }
        let mut queue = random_queue(&mut rng, cfg_a, 1, 2);
        let mut twin = queue[0].clone();
        twin.cfg = cfg_b;
        queue.push(twin);
        let groups = plan_fusion(&queue);
        for g in &groups {
            let c0 = queue[g[0]].cfg;
            assert!(g.iter().all(|&i| queue[i].cfg == c0), "mixed-config group: {groups:?}");
        }
        // the two same-config tiles share one launch; the twin is alone
        assert_eq!(groups.len(), 2, "{groups:?}");
        let (fused, _) = execute_fused(&queue);
        let unfused = execute_unfused(&queue);
        for (f, u) in fused.iter().zip(&unfused) {
            assert_eq!(
                f.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                u.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }
}

#[test]
fn interned_fusion_planning_groups_like_a_linear_scan() {
    // plan_fusion interns planes by content hash; its grouping decisions
    // must match a from-scratch linear scan that compares every tile
    // against every existing group representative bit-for-bit
    let mut rng = Rng::seeded(0x17E4);
    for round in 0..30 {
        let cfg = random_config(&mut rng);
        let planes = 1 + rng.below(3) as usize;
        let tiles = 1 + rng.below(10) as usize;
        let queue = random_queue(&mut rng, cfg, planes, tiles);
        let groups = plan_fusion(&queue);
        // reference: first-fit linear scan over full bitwise equality
        let eq = |x: &GemmTile, y: &GemmTile| {
            x.cfg == y.cfg
                && x.k == y.k
                && x.acc.len() == y.acc.len()
                && x.acc.iter().zip(&y.acc).all(|(p, q)| p.to_bits() == q.to_bits())
                && x.a.len() == y.a.len()
                && x.a.iter().zip(&y.a).all(|(p, q)| p.to_bits() == q.to_bits())
        };
        let mut want: Vec<Vec<usize>> = Vec::new();
        for (i, t) in queue.iter().enumerate() {
            match want.iter_mut().find(|g| eq(t, &queue[g[0]])) {
                Some(g) => g.push(i),
                None => want.push(vec![i]),
            }
        }
        assert_eq!(groups, want, "round {round} cfg {}", cfg.label());
    }
}

#[test]
fn quire_dot_batch_bit_identical_to_scalar_loop() {
    let mut rng = Rng::seeded(0x0B51);
    for _ in 0..15 {
        let n = [1usize, 4, 8][rng.below(3) as usize];
        let quire = QuirePdpuArch::new(PositFormat::p(13, 2), PositFormat::p(16, 2), n);
        let (rows, cols, k) = (
            1 + rng.below(5) as usize,
            1 + rng.below(5) as usize,
            1 + rng.below(40) as usize,
        );
        let w: Vec<f64> = (0..rows * k).map(|_| rng.log_uniform_signed(-8.0, 8.0)).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.log_uniform_signed(-8.0, 8.0)).collect();
        let acc: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let got = quire.dot_batch(&acc, &w, &x, k);
        for r in 0..rows {
            for c in 0..cols {
                let want = quire.dot_f64(acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(got[r * cols + c].to_bits(), want.to_bits(), "N={n} out[{r},{c}]");
            }
        }
    }
}

#[test]
fn prepared_operands_match_per_call_quantization() {
    // quantize-once must equal quantize-per-call: same packed lane words
    use pdpu::pdpu::PackedLane;
    let mut rng = Rng::seeded(0x9A4);
    let cfg = PdpuConfig::paper_default();
    let k = 17;
    let data: Vec<f64> = (0..4 * k).map(|_| rng.log_uniform_signed(-10.0, 10.0)).collect();
    let prepared = PreparedOperands::quantize(cfg.in_fmt, &data, k);
    for r in 0..4 {
        let fresh: Vec<_> = data[r * k..(r + 1) * k]
            .iter()
            .map(|&v| PackedLane::from_posit(Posit::from_f64(v, cfg.in_fmt)))
            .collect();
        assert_eq!(&fresh[..], prepared.row(r), "row {r}");
    }
}

#[test]
fn default_dot_batch_is_the_scalar_loop_for_baselines() {
    // the discrete/IEEE units use the defaulted dot_batch: verify it is
    // literally the dot_f64 loop for a representative of each family
    let units: Vec<Box<dyn DotArch>> = vec![
        Box::new(MulAddTreeDpu::new(IeeeArith { fmt: IeeeFormat::fp16() }, 4, "FPnew DPU")),
        Box::new(MulAddTreeDpu::new(
            PositArith { in_fmt: PositFormat::p(16, 2), out_fmt: PositFormat::p(16, 2) },
            4,
            "PACoGen DPU",
        )),
        Box::new(FmaCascadeDpu::new(IeeeArith { fmt: IeeeFormat::fp32() }, 1, "FPnew FMA")),
    ];
    let mut rng = Rng::seeded(0xDEF0);
    let (rows, cols, k) = (3usize, 4usize, 11usize);
    let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
    let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
    let acc: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    for u in &units {
        let got = u.dot_batch(&acc, &w, &x, k);
        for r in 0..rows {
            for c in 0..cols {
                let want = u.dot_f64(acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(got[r * cols + c].to_bits(), want.to_bits(), "{}", u.name());
            }
        }
    }
}

#[test]
fn conv2d_unchanged_by_batched_routing() {
    // end-to-end: the batched conv must reproduce the scalar per-pixel
    // loop bit-for-bit on a real workload for the fused unit
    use pdpu::dnn::dataset::conv1_workload;
    use pdpu::dnn::layers::conv2d;
    use pdpu::dnn::tensor::im2col_patch;

    let wl = conv1_workload(77, 12, 3);
    let cfg = PdpuConfig::paper_default();
    let arch = PdpuArch::new(cfg);
    let out = conv2d(&arch, &wl.image, &wl.weights, wl.stride, wl.pad);

    let (oc, kh, kw) = (wl.weights.shape()[0], wl.weights.shape()[2], wl.weights.shape()[3]);
    let klen = wl.weights.shape()[1] * kh * kw;
    let (oh, ow) = wl.out_hw();
    let mut patch = Vec::with_capacity(klen);
    for o in 0..oc {
        let wrow = &wl.weights.data()[o * klen..(o + 1) * klen];
        for oy in 0..oh {
            for ox in 0..ow {
                im2col_patch(&wl.image, oy, ox, kh, kw, wl.stride, wl.pad, &mut patch);
                let want = arch.dot_f64(0.0, wrow, &patch);
                let got = out.data()[(o * oh + oy) * ow + ox];
                assert_eq!(got.to_bits(), want.to_bits(), "out[{o},{oy},{ox}]");
            }
        }
    }
}
