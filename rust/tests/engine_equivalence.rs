//! Property tests for the batched GEMM engine: across random PDPU
//! configurations (uniform and mixed precision, N ∈ {1,4,8},
//! Wm ∈ 6..=96), `dot_batch`/`gemm` must be **bit-identical** to the
//! scalar `dot_f64`/`dot_chunked` loop, and invariant to the worker
//! thread count. This is the acceptance invariant of the engine: batching
//! is a scheduling optimization, never a numerics change.

use pdpu::baselines::{DotArch, IeeeArith, MulAddTreeDpu, PdpuArch};
use pdpu::baselines::{FmaCascadeDpu, IeeeFormat, PositArith};
use pdpu::engine::{BatchEngine, PreparedOperands};
use pdpu::pdpu::{Pdpu, PdpuConfig};
use pdpu::posit::{Posit, PositFormat};
use pdpu::testing::Rng;

/// Random valid PdpuConfig spanning the tested space: N ∈ {1,4,8},
/// Wm ∈ 6..=96, uniform and mixed input/output formats.
fn random_config(rng: &mut Rng) -> PdpuConfig {
    let n = [1usize, 4, 8][rng.below(3) as usize];
    loop {
        let wm = rng.range_i64(6, 96) as u32;
        let es = rng.range_i64(0, 2) as u32;
        let n_out = rng.range_i64(8, 32) as u32;
        let n_in = if rng.flip() {
            n_out // uniform
        } else {
            rng.range_i64(5, n_out as i64) as u32 // mixed: narrow inputs
        };
        if let Ok(cfg) = PdpuConfig::mixed(n_in, n_out, es, n, wm) {
            return cfg;
        }
    }
}

/// The scalar reference for one output element: quantize and run
/// `dot_chunked`, exactly as `PdpuArch::dot_f64` does.
fn scalar_dot(cfg: &PdpuConfig, acc: f64, a: &[f64], b: &[f64]) -> f64 {
    let unit = Pdpu::new(*cfg);
    let qa: Vec<Posit> = a.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
    let qb: Vec<Posit> = b.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
    unit.dot_chunked(Posit::from_f64(acc, cfg.out_fmt), &qa, &qb).to_f64()
}

#[test]
fn dot_batch_bit_identical_to_scalar_dot_chunked_across_configs() {
    let mut rng = Rng::seeded(0xB17_E4AC);
    for round in 0..60 {
        let cfg = random_config(&mut rng);
        let arch = PdpuArch::new(cfg);
        let rows = 1 + rng.below(5) as usize;
        let cols = 1 + rng.below(5) as usize;
        // k intentionally often not a multiple of N: exercises the padded tail
        let k = 1 + rng.below(40) as usize;
        let w: Vec<f64> = (0..rows * k).map(|_| rng.log_uniform_signed(-8.0, 8.0)).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.log_uniform_signed(-8.0, 8.0)).collect();
        let acc: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let got = arch.dot_batch(&acc, &w, &x, k);
        assert_eq!(got.len(), rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                let want = scalar_dot(&cfg, acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(
                    got[r * cols + c].to_bits(),
                    want.to_bits(),
                    "round {round} cfg {} out[{r},{c}]: got {} want {want}",
                    cfg.label(),
                    got[r * cols + c]
                );
                // and the trait's scalar entry point agrees too
                let via_dot_f64 = arch.dot_f64(acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(got[r * cols + c].to_bits(), via_dot_f64.to_bits());
            }
        }
    }
}

#[test]
fn gemm_invariant_to_worker_thread_count() {
    let mut rng = Rng::seeded(0x7764D);
    for _ in 0..12 {
        let cfg = random_config(&mut rng);
        let (rows, cols, k) = (
            1 + rng.below(12) as usize,
            1 + rng.below(9) as usize,
            1 + rng.below(50) as usize,
        );
        let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
        let acc: Vec<f64> = vec![0.0; rows];
        let baseline = BatchEngine::new(cfg).with_threads(1).gemm_f64(&acc, &w, &x, k);
        for threads in [2usize, 3, 7, 32] {
            let got = BatchEngine::new(cfg).with_threads(threads).gemm_f64(&acc, &w, &x, k);
            assert_eq!(
                baseline.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "cfg {} threads {threads}",
                cfg.label()
            );
        }
    }
}

#[test]
fn prepared_operands_match_per_call_quantization() {
    // quantize-once must equal quantize-per-call: same decoded planes
    use pdpu::posit::decode;
    let mut rng = Rng::seeded(0x9A4);
    let cfg = PdpuConfig::paper_default();
    let k = 17;
    let data: Vec<f64> = (0..4 * k).map(|_| rng.log_uniform_signed(-10.0, 10.0)).collect();
    let prepared = PreparedOperands::quantize(cfg.in_fmt, &data, k);
    for r in 0..4 {
        let fresh: Vec<_> = data[r * k..(r + 1) * k]
            .iter()
            .map(|&v| decode(Posit::from_f64(v, cfg.in_fmt)))
            .collect();
        assert_eq!(&fresh[..], prepared.row(r), "row {r}");
    }
}

#[test]
fn default_dot_batch_is_the_scalar_loop_for_baselines() {
    // the discrete/IEEE units use the defaulted dot_batch: verify it is
    // literally the dot_f64 loop for a representative of each family
    let units: Vec<Box<dyn DotArch>> = vec![
        Box::new(MulAddTreeDpu::new(IeeeArith { fmt: IeeeFormat::fp16() }, 4, "FPnew DPU")),
        Box::new(MulAddTreeDpu::new(
            PositArith { in_fmt: PositFormat::p(16, 2), out_fmt: PositFormat::p(16, 2) },
            4,
            "PACoGen DPU",
        )),
        Box::new(FmaCascadeDpu::new(IeeeArith { fmt: IeeeFormat::fp32() }, 1, "FPnew FMA")),
    ];
    let mut rng = Rng::seeded(0xDEF0);
    let (rows, cols, k) = (3usize, 4usize, 11usize);
    let w: Vec<f64> = (0..rows * k).map(|_| rng.normal()).collect();
    let x: Vec<f64> = (0..cols * k).map(|_| rng.normal()).collect();
    let acc: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    for u in &units {
        let got = u.dot_batch(&acc, &w, &x, k);
        for r in 0..rows {
            for c in 0..cols {
                let want = u.dot_f64(acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(got[r * cols + c].to_bits(), want.to_bits(), "{}", u.name());
            }
        }
    }
}

#[test]
fn conv2d_unchanged_by_batched_routing() {
    // end-to-end: the batched conv must reproduce the scalar per-pixel
    // loop bit-for-bit on a real workload for the fused unit
    use pdpu::dnn::dataset::conv1_workload;
    use pdpu::dnn::layers::conv2d;
    use pdpu::dnn::tensor::im2col_patch;

    let wl = conv1_workload(77, 12, 3);
    let cfg = PdpuConfig::paper_default();
    let arch = PdpuArch::new(cfg);
    let out = conv2d(&arch, &wl.image, &wl.weights, wl.stride, wl.pad);

    let (oc, kh, kw) = (wl.weights.shape()[0], wl.weights.shape()[2], wl.weights.shape()[3]);
    let klen = wl.weights.shape()[1] * kh * kw;
    let (oh, ow) = wl.out_hw();
    let mut patch = Vec::with_capacity(klen);
    for o in 0..oc {
        let wrow = &wl.weights.data()[o * klen..(o + 1) * klen];
        for oy in 0..oh {
            for ox in 0..ow {
                im2col_patch(&wl.image, oy, ox, kh, kw, wl.stride, wl.pad, &mut patch);
                let want = arch.dot_f64(0.0, wrow, &patch);
                let got = out.data()[(o * oh + oy) * ow + ox];
                assert_eq!(got.to_bits(), want.to_bits(), "out[{o},{oy},{ox}]");
            }
        }
    }
}
