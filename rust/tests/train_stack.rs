//! Property tests for the posit training subsystem (`rust/src/train/`):
//! gradient correctness against an FP64 analytic reference and a
//! finite-difference oracle, bit-equality of the GEMM-shaped backward
//! kernels with a scalar `dot_f64` backprop loop (the proof that backprop
//! rides `dot_batch`), loss-monotone training on the bundled dataset, and
//! bit-level parity of `SoftwareService::train_step` called directly vs.
//! through the coordinator wire path (engine thread and TCP server).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use pdpu::baselines::DotArch;
use pdpu::baselines::PdpuArch;
use pdpu::coordinator::{json, Metrics, Server, ServiceHandle, SoftwareService};
use pdpu::dnn::dataset::mnist_like;
use pdpu::dnn::layers::{linear_batch, relu};
use pdpu::dnn::Tensor;
use pdpu::pdpu::PdpuConfig;
use pdpu::testing::{diff, Rng};
use pdpu::train::{softmax_xent_batch, TrainGraph, Trainer};

/// Mini-batch from the shared differential-testing generators, wrapped
/// into the tensor shape the training graph expects.
fn random_batch(rng: &mut Rng, b: usize, d: usize, classes: usize) -> (Tensor, Vec<usize>) {
    let (xs, labels) = diff::random_batch(rng, b, d, classes);
    (Tensor::from_vec(&[b, d], xs), labels)
}

/// The FP64 analytic backward must match central finite differences of the
/// FP64 loss — the ground-truth check that the backward math (transposes,
/// ReLU gating, bias reduction) is the gradient of the forward pass.
#[test]
fn fp64_backward_matches_finite_differences() {
    let mut rng = Rng::seeded(0xFD_01);
    for round in 0..5 {
        let sizes = [5usize, 4, 3];
        let mut g = TrainGraph::fp64_reference(&sizes, 0x90 + round);
        let (xs, labels) = random_batch(&mut rng, 3, 5, 3);
        let trace = g.forward(&xs);
        let (_, dlogits) = softmax_xent_batch(trace.logits(), &labels);
        let grads = g.backward_f64(&trace, &dlogits);
        let eps = 1e-6;
        for l in 0..2 {
            let n_params = g.weights()[l].len();
            for idx in 0..n_params {
                let orig = g.weights()[l].data()[idx];
                let loss_at = |v: f64, g: &mut TrainGraph| {
                    g.weights_mut()[l].data_mut()[idx] = v;
                    let t = g.forward(&xs);
                    softmax_xent_batch(t.logits(), &labels).0
                };
                let hi = loss_at(orig + eps, &mut g);
                let lo = loss_at(orig - eps, &mut g);
                g.weights_mut()[l].data_mut()[idx] = orig;
                let fd = (hi - lo) / (2.0 * eps);
                let analytic = grads.dw[l].data()[idx];
                assert!(
                    (fd - analytic).abs() < 1e-4 * analytic.abs().max(1.0),
                    "round {round} dW[{l}][{idx}]: fd {fd} vs analytic {analytic}"
                );
            }
            // bias gradients the same way
            for o in 0..g.biases()[l].len() {
                let orig = g.biases()[l][o];
                g.biases_mut()[l][o] = orig + eps;
                let hi = softmax_xent_batch(g.forward(&xs).logits(), &labels).0;
                g.biases_mut()[l][o] = orig - eps;
                let lo = softmax_xent_batch(g.forward(&xs).logits(), &labels).0;
                g.biases_mut()[l][o] = orig;
                let fd = (hi - lo) / (2.0 * eps);
                let analytic = grads.db[l][o];
                assert!(
                    (fd - analytic).abs() < 1e-4 * analytic.abs().max(1.0),
                    "round {round} db[{l}][{o}]: fd {fd} vs analytic {analytic}"
                );
            }
        }
    }
}

/// The posit-routed backward (GEMMs through the batched PDPU engine,
/// quire-summed bias gradients) must track the FP64 analytic reference
/// within the quantization tolerance of the P(13/16,2) datapath.
#[test]
fn posit_backward_tracks_fp64_reference_within_tolerance() {
    let cfg = PdpuConfig::paper_default();
    let mut rng = Rng::seeded(0x90517_3A7);
    for round in 0..8 {
        let sizes = [12usize, 8, 4];
        let seed = 0x1000 + round;
        let gp = TrainGraph::new(cfg, &sizes, seed);
        let gf = TrainGraph::fp64_reference(&sizes, seed);
        let (xs, labels) = random_batch(&mut rng, 6, 12, 4);
        let tp = gp.forward(&xs);
        let tf = gf.forward(&xs);
        let (_, dp) = softmax_xent_batch(tp.logits(), &labels);
        let (_, df) = softmax_xent_batch(tf.logits(), &labels);
        let grads_p = gp.backward(&tp, &dp);
        let grads_f = gf.backward_f64(&tf, &df);
        for l in 0..2 {
            let num: f64 =
                grads_p.dw[l].data().iter().zip(grads_f.dw[l].data()).map(|(a, b)| (a - b).abs()).sum();
            let den: f64 = grads_f.dw[l].data().iter().map(|v| v.abs()).sum::<f64>().max(1e-3);
            assert!(num / den < 0.1, "round {round} dW[{l}] aggregate rel err {}", num / den);
            let bnum: f64 = grads_p.db[l].iter().zip(&grads_f.db[l]).map(|(a, b)| (a - b).abs()).sum();
            let bden: f64 = grads_f.db[l].iter().map(|v| v.abs()).sum::<f64>().max(1e-3);
            assert!(bnum / bden < 0.1, "round {round} db[{l}] aggregate rel err {}", bnum / bden);
        }
    }
}

/// The backward kernels must be *bit-identical* to a from-scratch scalar
/// backprop written with `dot_f64` calls: weight-grad and activation-grad
/// really are `dot_batch` tiles over transposed planes (and `dot_batch`
/// itself is engine-vs-scalar property-tested in engine_equivalence.rs).
#[test]
fn backward_kernels_bit_equal_scalar_dot_loop() {
    let cfg = PdpuConfig::paper_default();
    let arch = PdpuArch::new(cfg);
    let mut rng = Rng::seeded(0xB17_6AD);
    for round in 0..5 {
        let (din, dh, dout, b) = (7usize, 5usize, 3usize, 4usize);
        let g = TrainGraph::new(cfg, &[din, dh, dout], 0x2000 + round);
        let (xs, labels) = random_batch(&mut rng, b, din, dout);
        let trace = g.forward(&xs);
        let (_, dlogits) = softmax_xent_batch(trace.logits(), &labels);
        let grads = g.backward(&trace, &dlogits);

        // recompute the hidden activations with the public layer ops
        let z_hidden = linear_batch(&arch, &xs, &g.weights()[0], &g.biases()[0]);
        let mut a_hidden = z_hidden.clone();
        relu(a_hidden.data_mut());

        // scalar-loop backprop, layer 1 (dz = dlogits):
        // dW1[o,j] = dot(dlogits[:,o], a_hidden[:,j])
        for o in 0..dout {
            for j in 0..dh {
                let col_dz: Vec<f64> = (0..b).map(|i| dlogits.data()[i * dout + o]).collect();
                let col_a: Vec<f64> = (0..b).map(|i| a_hidden.data()[i * dh + j]).collect();
                let want = arch.dot_f64(0.0, &col_dz, &col_a);
                assert_eq!(
                    grads.dw[1].data()[o * dh + j].to_bits(),
                    want.to_bits(),
                    "round {round} dW1[{o},{j}]"
                );
            }
        }
        // activation grad + ReLU gate: dz0[i,j] = 1{z>0}·dot(dlogits[i,:], W1[:,j])
        let mut dz0 = vec![0.0; b * dh];
        for i in 0..b {
            for j in 0..dh {
                let row: Vec<f64> = (0..dout).map(|o| dlogits.data()[i * dout + o]).collect();
                let wcol: Vec<f64> = (0..dout).map(|o| g.weights()[1].data()[o * dh + j]).collect();
                let da = arch.dot_f64(0.0, &row, &wcol);
                dz0[i * dh + j] = if z_hidden.data()[i * dh + j] > 0.0 { da } else { 0.0 };
            }
        }
        // scalar-loop layer 0 weight grad from the reconstructed dz0
        for o in 0..dh {
            for j in 0..din {
                let col_dz: Vec<f64> = (0..b).map(|i| dz0[i * dh + o]).collect();
                let col_x: Vec<f64> = (0..b).map(|i| xs.data()[i * din + j]).collect();
                let want = arch.dot_f64(0.0, &col_dz, &col_x);
                assert_eq!(
                    grads.dw[0].data()[o * din + j].to_bits(),
                    want.to_bits(),
                    "round {round} dW0[{o},{j}]"
                );
            }
        }
    }
}

/// Loss-monotone smoke: epochs of posit SGD over the bundled dataset
/// generator must strictly decrease the epoch loss.
#[test]
fn epoch_loss_strictly_decreases_on_bundled_dataset() {
    let ds = mnist_like(5, 32, 2);
    let mut t = Trainer::new(PdpuConfig::paper_default(), &[784, 4, 2], 0.08, 0x5EED);
    let stats = t.fit(&ds, 2, 8);
    assert!(
        stats[1].mean_loss < stats[0].mean_loss,
        "epoch loss must decrease: {} → {}",
        stats[0].mean_loss,
        stats[1].mean_loss
    );
    assert!(stats.iter().all(|s| s.mean_loss.is_finite()));
}

/// Bit-level parity: the same train-step sequence must produce bitwise
/// identical losses (and leave bitwise identical served models) whether
/// `SoftwareService::train_step` is called directly, through the engine
/// thread (`ServiceHandle`), or over the TCP `train` wire op.
#[test]
fn train_step_direct_vs_wire_paths_bit_identical() {
    let cfg = PdpuConfig::paper_default();
    let (sizes, batch, mkn, seed) = (vec![8usize, 6, 3], 4usize, (2usize, 2usize, 2usize), 0xAB5Eu64);
    let direct = SoftwareService::new(cfg, &sizes, batch, mkn, seed).unwrap();
    let handle = ServiceHandle::start_software(cfg, sizes.clone(), batch, mkn, seed).unwrap();
    let metrics = Arc::new(Metrics::new());
    let tcp_backend = ServiceHandle::start_software(cfg, sizes.clone(), batch, mkn, seed).unwrap();
    let server = Server::start("127.0.0.1:0", tcp_backend.clone(), metrics.clone()).expect("server");
    let stream = TcpStream::connect(server.addr).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);

    let mut rng = Rng::seeded(0x7E57_AB);
    for step in 0..6 {
        let images: Vec<Vec<f32>> =
            (0..batch).map(|_| (0..8).map(|_| rng.uniform(-1.0, 1.0) as f32).collect()).collect();
        let labels: Vec<u32> = (0..batch).map(|_| rng.below(3) as u32).collect();

        let want = direct.train_step(&images, &labels).expect("direct step");
        let via_engine = handle.train_step(images.clone(), labels.clone()).expect("engine step");
        assert_eq!(want.to_bits(), via_engine.to_bits(), "step {step}: engine wire path diverged");

        let rows: Vec<json::Json> = images
            .iter()
            .map(|im| json::Json::arr_f64(&im.iter().map(|&v| v as f64).collect::<Vec<_>>()))
            .collect();
        let req = json::Json::obj(vec![
            ("op", json::Json::Str("train".into())),
            ("images", json::Json::Arr(rows)),
            ("labels", json::Json::arr_f64(&labels.iter().map(|&l| l as f64).collect::<Vec<_>>())),
        ]);
        writer.write_all((req.to_string() + "\n").as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("ok"), Some(&json::Json::Bool(true)), "{line}");
        let via_tcp = v.get("loss").unwrap().as_f64().unwrap() as f32;
        assert_eq!(want.to_bits(), via_tcp.to_bits(), "step {step}: TCP wire path diverged");
    }

    // all three served models ended in the same state: identical logits
    let probe: Vec<Vec<f32>> = (0..2).map(|i| vec![0.25 * (i + 1) as f32; 8]).collect();
    let a = direct.infer_batch(&probe).unwrap();
    let b = handle.infer_batch(probe.clone()).unwrap();
    let c = tcp_backend.infer_batch(probe).unwrap();
    let bits = |v: &Vec<Vec<f32>>| -> Vec<u32> { v.iter().flatten().map(|x| x.to_bits()).collect() };
    assert_eq!(bits(&a), bits(&b));
    assert_eq!(bits(&a), bits(&c));

    // the stats wire op reports the train counters
    writer.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.get("train_steps").unwrap().as_f64(), Some(6.0), "{line}");
    assert_eq!(v.get("train_examples").unwrap().as_f64(), Some(24.0), "{line}");

    // malformed train requests error without killing the connection
    writer.write_all(b"{\"op\":\"train\",\"images\":[[1,2]],\"labels\":[0,1]}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("labels"), "{line}");
    // negative / fractional labels are rejected, not saturated into class 0
    for bad in ["-1", "2.5"] {
        let req = format!(
            "{{\"op\":\"train\",\"images\":[[{}]],\"labels\":[{bad}]}}\n",
            vec!["0.1"; 8].join(",")
        );
        writer.write_all(req.as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("non-negative integer"), "label {bad}: {line}");
    }

    handle.shutdown();
    tcp_backend.shutdown();
}
