//! Tier-1 gate for `pdpu lint` (`rust/src/analysis/`): the tree itself
//! must be clean, and — so a regression in the analyzer can't silently
//! pass a dirty tree — every rule must demonstrably *fire* on a fixture
//! that violates it, and the suppression pragma must demonstrably work.

use std::path::Path;

use pdpu::analysis::lexer::SourceFile;
use pdpu::analysis::{lint_source, rules, run_lint};

/// The whole repo passes its own lint — the same check `pdpu lint` and CI
/// run. A failure message lists every diagnostic.
#[test]
fn tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let diags = run_lint(root).expect("lint walked the tree");
    let listing: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(diags.is_empty(), "pdpu lint found {} violation(s):\n{}", diags.len(), listing.join("\n"));
}

/// R1 fires on `.unwrap()`, `.expect(…)`, panicking macros, and literal
/// subscripts in non-test coordinator code — and nowhere else.
#[test]
fn r1_panic_freedom_fires_on_fixture() {
    let src = "fn f(v: Vec<u64>) -> u64 {\n\
               let a = v.first().copied().unwrap();\n\
               let b: u64 = v.iter().sum::<u64>();\n\
               if b == 0 { panic!(\"empty\"); }\n\
               a + v[0]\n\
               }\n";
    let diags = lint_source("coordinator/fixture.rs", src);
    assert_eq!(diags.len(), 3, "unwrap + panic! + v[0]: {diags:?}");
    assert!(diags.iter().all(|d| d.rule == "panic-freedom"));
    assert_eq!([diags[0].line, diags[1].line, diags[2].line], [2, 4, 5]);
    // same source outside the serving tier is out of scope
    assert!(lint_source("experiments/fixture.rs", src).is_empty());
    // test code inside the serving tier is out of scope
    let in_test = format!("#[cfg(test)]\nmod tests {{\n{src}\n}}\n");
    assert!(lint_source("coordinator/fixture.rs", &in_test).is_empty());
}

/// R2 fires on allocating calls inside `*_into` stage kernels and inside
/// `// pdpu-lint: hot-path`-marked functions; scratch-reuse ops pass.
#[test]
fn r2_alloc_freedom_fires_on_fixture() {
    let stage = "pub fn s9_widen_into(xs: &[u64], out: &mut Vec<u64>) {\n\
                 out.clear();\n\
                 let ys = xs.to_vec();\n\
                 out.extend(ys);\n\
                 }\n";
    let diags = lint_source("pdpu/stages/s9_widen.rs", stage);
    assert!(
        diags.iter().any(|d| d.rule == "alloc-freedom" && d.line == 3),
        ".to_vec() in an _into kernel: {diags:?}"
    );
    // the same kernel outside pdpu/stages/ is out of scope…
    assert!(lint_source("dnn/fixture.rs", stage).is_empty());
    // …unless it carries the hot-path marker, which works anywhere
    let hot = "// pdpu-lint: hot-path\nfn kernel(xs: &[u64]) -> Vec<u64> { xs.iter().map(|x| x + 1).collect() }\n";
    let diags = lint_source("dnn/fixture.rs", hot);
    assert_eq!(diags.len(), 1, ".collect() in a hot-path fn: {diags:?}");
    assert_eq!(diags[0].rule, "alloc-freedom");
    // allocation-free scratch reuse is exactly what the rule protects
    let clean = "// pdpu-lint: hot-path\nfn kernel(xs: &[u64], out: &mut Vec<u64>) { out.clear(); out.extend(xs); }\n";
    assert!(lint_source("dnn/fixture.rs", clean).is_empty());
}

/// R3 fires on hash-container iteration and clock/entropy reads in
/// result-affecting files; keyed lookups stay legal.
#[test]
fn r3_determinism_fires_on_fixture() {
    let src = "use std::collections::HashMap;\n\
               fn f(m: &HashMap<u32, u32>) -> u64 {\n\
               let mut s = 0u64;\n\
               for (_k, v) in m.iter() { s += u64::from(*v); }\n\
               let t = std::time::Instant::now();\n\
               let _ = t;\n\
               s\n\
               }\n";
    let diags = lint_source("pdpu/fixture.rs", src);
    assert_eq!(diags.len(), 2, "m.iter() + Instant::now(): {diags:?}");
    assert!(diags.iter().all(|d| d.rule == "determinism"));
    // keyed lookups are order-free and allowed
    let lookups = "use std::collections::HashMap;\n\
                   fn f(m: &mut HashMap<u32, u32>) -> Option<u32> { m.insert(1, 2); m.get(&1).copied() }\n";
    assert!(lint_source("pdpu/fixture.rs", lookups).is_empty());
    // the coordinator is in the clock scope: raw clock reads must route
    // through crate::obs::clock instead
    let raw_clock = "fn f() { let _ = std::time::Instant::now(); }";
    let diags = lint_source("coordinator/batcher.rs", raw_clock);
    assert_eq!(diags.len(), 1, "raw Instant::now in the coordinator: {diags:?}");
    assert_eq!(diags[0].rule, "determinism");
    // …but hash iteration there stays unflagged (clock scope only): the
    // same fixture that drew two diags in pdpu/ draws just the clock one
    let lines: Vec<usize> = lint_source("coordinator/batcher.rs", src).iter().map(|d| d.line).collect();
    assert_eq!(lines, vec![5], "only the Instant::now line fires in the coordinator");
    // obs/ is the sanctioned clock site — clean by construction
    let clock_site = "pub fn now() -> std::time::Instant { std::time::Instant::now() }";
    assert!(lint_source("obs/clock.rs", clock_site).is_empty());
    // the sanctioned call spelling is clean everywhere, coordinator included
    assert!(lint_source("coordinator/batcher.rs", "fn f() { let _ = crate::obs::clock::now(); }").is_empty());
}

/// R4 fires when a stage references a later stage or reaches outside the
/// stage dataflow; earlier stages and the config stay legal.
#[test]
fn r4_stage_isolation_fires_on_fixture() {
    let src = "use crate::engine::BatchEngine;\n\
               use crate::pdpu::stages::s5_normalize::S5;\n\
               fn f(cfg: &crate::pdpu::PdpuConfig) { let _ = cfg; }\n";
    let diags = lint_source("pdpu/stages/s3_fixture.rs", src);
    assert!(diags.iter().all(|d| d.rule == "stage-isolation"));
    assert!(diags.iter().any(|d| d.line == 1), "crate::engine from a stage: {diags:?}");
    assert!(diags.iter().any(|d| d.line == 2), "s5_* from S3: {diags:?}");
    assert!(!diags.iter().any(|d| d.line == 3), "crate::pdpu::PdpuConfig is legal: {diags:?}");
    // the same record is fine from S6 (s5 is an earlier stage there)
    let s6 = "use super::s5_normalize::S5;\nfn f(x: S5) { let _ = x; }\n";
    assert!(lint_source("pdpu/stages/s6_fixture.rs", s6).is_empty());
}

/// R5 fires in both directions: an op served but undocumented, and an op
/// documented but unserved; missing table markers are their own error.
#[test]
fn r5_wire_ops_fires_on_fixture() {
    let server_src = "fn handle_request(op: Option<&str>) -> u32 {\n\
                      match op {\n\
                      Some(\"ping\") => 1,\n\
                      Some(\"infer\") => 2,\n\
                      _ => 0,\n\
                      }\n\
                      }\n";
    let server = SourceFile::parse("coordinator/server.rs", server_src);
    let docs = "preamble\n<!-- wire-ops:begin -->\n| op | meaning |\n|---|---|\n\
                | `ping` | liveness |\n| `stats` | counters |\n<!-- wire-ops:end -->\n";
    let diags = rules::r5_wire_ops::check(&server, docs, "docs/ARCHITECTURE.md");
    assert_eq!(diags.len(), 2, "served-undocumented + documented-unserved: {diags:?}");
    assert!(diags.iter().any(|d| d.file.starts_with("rust/src/") && d.message.contains("'infer'")));
    assert!(diags.iter().any(|d| d.file.starts_with("docs/") && d.message.contains("'stats'")));
    // exact agreement is clean
    let docs_ok = "<!-- wire-ops:begin -->\n| op |\n|---|\n| `ping` |\n| `infer` |\n<!-- wire-ops:end -->\n";
    assert!(rules::r5_wire_ops::check(&server, docs_ok, "docs/ARCHITECTURE.md").is_empty());
    // a doc without the markers cannot satisfy the rule
    let no_markers = rules::r5_wire_ops::check(&server, "no table here\n", "docs/ARCHITECTURE.md");
    assert_eq!(no_markers.len(), 1);
    assert!(no_markers[0].message.contains("wire-ops:begin"));
}

/// The suppression pragma needs the right rule *and* a reason; a bare or
/// reasonless pragma is itself a diagnostic and suppresses nothing.
#[test]
fn suppression_pragma_grammar_is_enforced() {
    let violation = "fn f(v: Vec<u64>) -> u64 { v.first().copied().unwrap() }\n";
    let suppressed =
        format!("// pdpu-lint: allow(panic-freedom) — fixture: suppression must cover the next line\n{violation}");
    assert!(lint_source("coordinator/fixture.rs", &suppressed).is_empty());
    let reasonless = format!("// pdpu-lint: allow(panic-freedom)\n{violation}");
    let diags = lint_source("coordinator/fixture.rs", &reasonless);
    assert!(diags.iter().any(|d| d.rule == "pragma"), "reasonless pragma is malformed: {diags:?}");
    assert!(diags.iter().any(|d| d.rule == "panic-freedom"), "and suppresses nothing: {diags:?}");
}
