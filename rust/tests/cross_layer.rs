//! Cross-layer consistency: the Rust bit-exact posit library (L3 ground
//! truth) vs the functional contracts the Python layers rely on. These
//! tests run without artifacts — they pin the *rust side* of the
//! agreement that `python/tests/test_posit_emu.py` checks from the other
//! direction.

use pdpu::baselines::{DotArch, PdpuArch};
use pdpu::pdpu::PdpuConfig;
use pdpu::posit::{Posit, PositFormat};
use pdpu::testing::Rng;

/// The jnp quantizer (value-level) and the Rust encoder (bit-level) must
/// produce the same *value grid*: quantizing any f64 twice through
/// from_f64 is idempotent, and the grid is closed under the kernel's
/// Q_out(Q_in·Q_in accumulation) discipline.
#[test]
fn quantization_grid_is_idempotent_and_closed() {
    let mut rng = Rng::seeded(1);
    for &(n, es) in &[(8u32, 2u32), (10, 2), (13, 2), (16, 2)] {
        let fmt = PositFormat::p(n, es);
        for _ in 0..2_000 {
            let x = rng.log_uniform_signed(-30.0, 30.0);
            let q1 = Posit::from_f64(x, fmt).to_f64();
            let q2 = Posit::from_f64(q1, fmt).to_f64();
            assert_eq!(q1, q2, "P({n},{es}) x={x}");
        }
    }
}

/// The L1 kernel contract: Q_out(Σ Q_in(a)·Q_in(b)) over f32 accumulation
/// differs from the bit-exact PDPU (Wm-truncated) by bounded ulps. This is
/// what lets the serving stack (Pallas artifact) and the accuracy
/// experiments (Rust functional model) describe the same hardware.
#[test]
fn kernel_semantics_close_to_pdpu_functional_model() {
    let in_fmt = PositFormat::p(13, 2);
    let out_fmt = PositFormat::p(16, 2);
    let pdpu = PdpuArch::new(PdpuConfig::mixed(13, 16, 2, 4, 14).unwrap());
    let mut rng = Rng::seeded(7);
    let mut max_rel = 0f64;
    for _ in 0..300 {
        let k = 32;
        let a: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        // kernel semantics (f32 accumulate, one output rounding)
        let mut acc = 0f32;
        for (x, y) in a.iter().zip(&b) {
            let qx = Posit::from_f64(*x, in_fmt).to_f64() as f32;
            let qy = Posit::from_f64(*y, in_fmt).to_f64() as f32;
            acc += qx * qy;
        }
        let kernel = Posit::from_f64(acc as f64, out_fmt).to_f64();
        // hardware semantics (Wm=14 fused chunks)
        let hw = pdpu.dot_f64(0.0, &a, &b);
        // The two accumulators legitimately differ by their truncation
        // grids; on cancellation-heavy sums the OUTPUT-relative error is
        // unbounded, so bound the divergence against the dot product's
        // magnitude scale Σ|aᵢbᵢ| instead: chunked Wm=14 truncation loses
        // < chunks·(N+1) grid-ulps ≈ Σ|ab|·2^-9 worst case.
        let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        let rel = (kernel - hw).abs() / scale.max(1e-9);
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 2f64.powi(-9), "kernel vs PDPU functional model diverged: {max_rel}");
}

/// Golden vectors: the exact values the `pdpu quantize` CLI (used by the
/// Python cross-layer test) must print.
#[test]
fn quantize_golden_vectors() {
    let p8 = PositFormat::p(8, 2);
    for (x, want) in [
        (11.0, 11.0),
        (1.06, 1.0),
        (3.7, 3.75),
        (1e30, 16777216.0),
        (-1e30, -16777216.0),
        (3150529.25, 1048576.0), // the (e, frac) joint-rounding regression
    ] {
        assert_eq!(Posit::from_f64(x, p8).to_f64(), want, "x={x}");
    }
}
