//! Long-haul fuzz for the lane-packed fast path (`#[ignore]`d; run by the
//! advisory CI job via `cargo test --release -- --ignored`).
//!
//! Random [`PdpuConfig`]s — including dot sizes that cross the
//! `MAX_FAST_LANES` boundary into the staged fallback — are driven through
//! `dot`/`dot_with`/`dot_prepared`/`gemm` on adversarial and
//! cancellation-heavy operands, asserting scalar↔vectorized bit-identity
//! throughout, plus one test checking that the numerics observatory's
//! tallies (saturation, minpos clamps, NaR) agree with a recount of the
//! actual outputs.
//!
//! Every `gemm_posit` launch now records at the single
//! `BatchEngine::observe_launch` boundary, so sibling tests in this binary
//! bump the process-global counters too. The parity test therefore asserts
//! **exact** deltas against its own uniquely-guarded site-registry entry
//! (`obs::numerics::snapshot`) and only monotone `≥` on the globals; its
//! expected outputs come from the scalar `dot_chunked` path, which never
//! touches the registry.

use pdpu::engine::{BatchEngine, PreparedOperands};
use pdpu::pdpu::{Pdpu, PdpuConfig, MAX_FAST_LANES};
use pdpu::posit::Posit;
use pdpu::testing::diff::{
    adversarial_vector, assert_dot_paths_bit_identical, cancellation_pair, random_config,
    random_config_with_n, rand_pattern, special,
};
use pdpu::testing::Rng;

/// Dot sizes straddling the fast-path boundary (N ≤ 64 fused, above staged).
const N_CHOICES: [usize; 12] = [1, 2, 3, 4, 7, 8, 16, 32, 63, 64, 65, 96];

#[test]
#[ignore = "long-haul fuzz: random configs through every dot path; run via the advisory CI job"]
fn dot_paths_bit_identical_across_random_configs() {
    let mut rng = Rng::seeded(0xF0220_001);
    for _ in 0..30_000 {
        let n = N_CHOICES[rng.below(N_CHOICES.len() as u64) as usize];
        let cfg = random_config_with_n(&mut rng, n);
        let (a, b) = if rng.flip() {
            (
                adversarial_vector(&mut rng, cfg.in_fmt, n),
                adversarial_vector(&mut rng, cfg.in_fmt, n),
            )
        } else {
            cancellation_pair(&mut rng, cfg.in_fmt, n)
        };
        let acc = if rng.below(4) == 0 {
            special(&mut rng, cfg.out_fmt)
        } else {
            rand_pattern(&mut rng, cfg.out_fmt)
        };
        assert_dot_paths_bit_identical(&cfg, acc, &a, &b);
    }
}

#[test]
#[ignore = "long-haul fuzz: batched GEMM vs the scalar chunked loop; run via the advisory CI job"]
fn gemm_bit_identical_to_scalar_chunked_loop() {
    let mut rng = Rng::seeded(0xF0220_002);
    for round in 0..2_000 {
        let cfg = random_config(&mut rng);
        let unit = Pdpu::new(cfg);
        let engine = BatchEngine::new(cfg);
        let (rows, cols) = (1 + rng.below(4) as usize, 1 + rng.below(4) as usize);
        let k = 1 + rng.below(3 * MAX_FAST_LANES as u64) as usize; // tails + multi-chunk
        let w = adversarial_vector(&mut rng, cfg.in_fmt, rows * k);
        let x = adversarial_vector(&mut rng, cfg.in_fmt, cols * k);
        let acc: Vec<Posit> = (0..rows).map(|_| rand_pattern(&mut rng, cfg.out_fmt)).collect();
        let wp = PreparedOperands::from_posits(cfg.in_fmt, &w, k);
        let xp = PreparedOperands::from_posits(cfg.in_fmt, &x, k);
        let got = engine.gemm_posit(&acc, &wp, &xp);
        for r in 0..rows {
            for c in 0..cols {
                let want = unit.dot_chunked(acc[r], &w[r * k..(r + 1) * k], &x[c * k..(c + 1) * k]);
                assert_eq!(
                    got[r * cols + c].bits(),
                    want.bits(),
                    "round {round} cfg {} out[{r},{c}]",
                    cfg.label()
                );
            }
        }
    }
}

/// Mirror of `obs::numerics::record_launch`'s classification: (maxpos,
/// minpos, nar) tallies over a launch's posit outputs.
fn classify(outs: &[Posit]) -> (u64, u64, u64) {
    let (mut maxpos, mut minpos, mut nar) = (0u64, 0u64, 0u64);
    for p in outs {
        if p.is_nar() {
            nar += 1;
            continue;
        }
        if p.is_zero() {
            continue;
        }
        let fmt = p.format();
        let bits = p.bits();
        let sign_bit = 1u32 << (fmt.n() - 1);
        let abs = if bits & sign_bit != 0 { bits.wrapping_neg() & fmt.mask() } else { bits };
        if abs == fmt.maxpos_bits() {
            maxpos += 1;
        } else if abs == fmt.minpos_bits() {
            minpos += 1;
        }
    }
    (maxpos, minpos, nar)
}

#[test]
#[ignore = "long-haul fuzz: obs numerics counters vs output recount; run via the advisory CI job"]
fn numerics_counters_agree_with_outputs() {
    use pdpu::obs::numerics::{Site, SiteGuard, SiteKind};
    let mut rng = Rng::seeded(0xF0220_003);
    for round in 0..500 {
        let cfg = random_config(&mut rng);
        let unit = Pdpu::new(cfg);
        let engine = BatchEngine::new(cfg);
        let (rows, cols) = (1 + rng.below(3) as usize, 1 + rng.below(3) as usize);
        let k = 1 + rng.below(24) as usize;
        // huge dynamic range forces ±maxpos saturation and ±minpos clamps;
        // injected NaNs quantize to NaR and must poison whole output rows
        let mut w: Vec<f64> = (0..rows * k).map(|_| rng.log_uniform_signed(-80.0, 80.0)).collect();
        let x: Vec<f64> = (0..cols * k).map(|_| rng.log_uniform_signed(-80.0, 80.0)).collect();
        if rng.flip() {
            let slot = rng.below((rows * k) as u64) as usize;
            w[slot] = f64::NAN;
        }
        let acc: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();

        // expected outputs via the scalar entry point, which records nothing
        let wq: Vec<Posit> = w.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
        let xq: Vec<Posit> = x.iter().map(|&v| Posit::from_f64(v, cfg.in_fmt)).collect();
        let accp: Vec<Posit> = acc.iter().map(|&v| Posit::from_f64(v, cfg.out_fmt)).collect();
        let mut outs = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                outs.push(unit.dot_chunked(accp[r], &wq[r * k..(r + 1) * k], &xq[c * k..(c + 1) * k]));
            }
        }
        let (exp_max, exp_min, exp_nar) = classify(&outs);

        // one launch under a site no other test can collide with: the
        // registry entry's tallies are then exact, not merely monotone
        let site = Site::new(SiteKind::Gemm, 100_000 + round as i32);
        let before = pdpu::obs::numerics();
        let got = {
            let _guard = SiteGuard::enter(site);
            engine.gemm_f64(&acc, &w, &x, k)
        };
        let after = pdpu::obs::numerics();

        let entry = pdpu::obs::numerics::snapshot()
            .into_iter()
            .find(|e| e.site == site)
            .unwrap_or_else(|| panic!("round {round}: launch not recorded at the guarded site"));
        assert_eq!(entry.stats.launches, 1, "round {round} launches");
        assert_eq!(entry.stats.outputs, (rows * cols) as u64, "round {round} outputs");
        assert_eq!(entry.stats.sat_maxpos, exp_max, "round {round} maxpos");
        assert_eq!(entry.stats.sat_minpos, exp_min, "round {round} minpos");
        assert_eq!(entry.stats.nar, exp_nar, "round {round} nar");

        // the site-attributed tallies also feed the process-global counters
        // (sibling tests run concurrently, so only `≥` is assertable there)
        assert!(after.sat_maxpos - before.sat_maxpos >= exp_max, "round {round} global maxpos");
        assert!(after.sat_minpos - before.sat_minpos >= exp_min, "round {round} global minpos");
        assert!(after.nar - before.nar >= exp_nar, "round {round} global nar");

        // and the f64 facade returns exactly the posit outputs it counted
        for (g, p) in got.iter().zip(&outs) {
            assert_eq!(g.to_bits(), p.to_f64().to_bits(), "round {round}");
        }
    }
}
