//! Integration tests for the sharded serving tier: the hard invariant is
//! that sharding, dynamic batching, cross-request fusion, and the
//! cross-batch plane cache are *pure routing* — every output is bitwise
//! identical to the single-threaded, uncached, unfused oracle — while the
//! cache actually hits across batches and admission control actually
//! sheds under saturation.

use std::sync::Arc;

use pdpu::coordinator::{Metrics, ServerPolicy, ServiceHandle, ServingTier, SoftwareService, TierReply};
use pdpu::pdpu::PdpuConfig;

const MKN: (usize, usize, usize) = (4, 9, 3);

fn software(planes: usize) -> SoftwareService {
    SoftwareService::new(PdpuConfig::paper_default(), &[8, 4], 8, MKN, 0x7E57)
        .expect("valid test config")
        .with_plane_cache_capacity(planes)
}

fn tier(policy: ServerPolicy, planes: usize) -> (Arc<ServingTier>, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let handle = ServiceHandle::from_software(software(planes));
    (Arc::new(ServingTier::new(handle, metrics.clone(), policy)), metrics)
}

fn plane_a(p: usize) -> Vec<f32> {
    let (m, k, _) = MKN;
    (0..m * k).map(|i| ((p * 7 + i) % 11) as f32 * 0.125 - 0.5).collect()
}

fn operand_b(seed: usize) -> Vec<f32> {
    let (_, k, n) = MKN;
    (0..k * n).map(|i| ((seed * 13 + 3 * i) % 9) as f32 * 0.25 - 1.0).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// 4 shards, 4 client threads, 100 GEMMs over 3 shared weight planes:
/// every reply is bitwise identical to a direct, uncached, unfused
/// `SoftwareService::gemm` on a *separate* service instance — and the
/// shared planes actually hit the cache.
#[test]
fn sharded_cached_fused_gemm_is_bitwise_identical_to_the_uncached_oracle() {
    let policy = ServerPolicy { shards: 4, max_inflight: 0, ..ServerPolicy::default() };
    let (tier, metrics) = tier(policy, 8);
    let oracle = software(0); // no cache, and `gemm` is also unfused

    let mut handles = Vec::new();
    for t in 0..4usize {
        let tier = tier.clone();
        handles.push(std::thread::spawn(move || {
            let mut got = Vec::new();
            for i in 0..25usize {
                let a = plane_a((t + i) % 3);
                let b = operand_b(t * 100 + i);
                match tier.gemm(tier.assign_shard(), a.clone(), b.clone(), None) {
                    TierReply::Ok(c) => got.push((a, b, c)),
                    other => panic!("unlimited budget must serve, got {other:?}"),
                }
            }
            got
        }));
    }
    let mut served = 0usize;
    for h in handles {
        for (a, b, c) in h.join().expect("client thread") {
            let want = oracle.gemm(&a, &b).expect("oracle gemm");
            assert_eq!(bits(&c), bits(&want), "tier output diverged from the oracle");
            served += 1;
        }
    }
    assert_eq!(served, 100);
    let s = metrics.snapshot();
    assert_eq!(s.requests, 100);
    assert_eq!(s.responses, 100);
    assert_eq!(s.errors, 0);
    assert_eq!(s.shed_requests, 0);

    let cache = tier.plane_cache_stats();
    assert!(cache.hits > 0, "3 shared planes over 100 requests must hit: {cache:?}");
    assert!(cache.entries >= 1 && cache.entries <= 3, "only 3 distinct planes exist: {cache:?}");
}

/// The cache is *cross-batch*: sequential single-request batches on one
/// shard reuse the prepared plane from earlier batches.
#[test]
fn plane_cache_hits_accumulate_across_batches() {
    let policy = ServerPolicy { shards: 1, ..ServerPolicy::default() };
    let (tier, _metrics) = tier(policy, 16);
    let oracle = software(0);
    let (a, b) = (plane_a(0), operand_b(42));
    let want = bits(&oracle.gemm(&a, &b).expect("oracle gemm"));
    for round in 0..5 {
        match tier.gemm(0, a.clone(), b.clone(), None) {
            TierReply::Ok(c) => assert_eq!(bits(&c), want, "round {round} diverged"),
            other => panic!("round {round}: {other:?}"),
        }
    }
    let cache = tier.plane_cache_stats();
    assert_eq!(cache.misses, 1, "one cold quantization: {cache:?}");
    assert_eq!(cache.hits, 4, "four warm batches: {cache:?}");
    assert_eq!(cache.entries, 1, "{cache:?}");
}

/// A one-permit budget under concurrent load sheds — and sheds are
/// counted as requests but never as responses or errors.
#[test]
fn tier_sheds_when_the_admission_budget_saturates() {
    let policy = ServerPolicy { shards: 1, max_inflight: 1, ..ServerPolicy::default() };
    let (tier, metrics) = tier(policy, 8);
    const THREADS: usize = 8;
    const PER_THREAD: usize = 20;
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let tier = tier.clone();
        handles.push(std::thread::spawn(move || {
            let mut sheds = 0u64;
            for i in 0..PER_THREAD {
                match tier.gemm(tier.assign_shard(), plane_a(t % 3), operand_b(t * 50 + i), None) {
                    TierReply::Ok(_) => {}
                    TierReply::Shed => sheds += 1,
                    TierReply::Err(e) => panic!("valid gemm errored: {e}"),
                }
            }
            sheds
        }));
    }
    let sheds: u64 = handles.into_iter().map(|h| h.join().expect("client thread")).sum();
    assert!(sheds > 0, "one permit across 8 hammering threads must shed");
    let s = metrics.snapshot();
    let total = (THREADS * PER_THREAD) as u64;
    assert_eq!(s.shed_requests, sheds);
    assert_eq!(s.requests, total, "sheds still count as requests");
    assert_eq!(s.responses, total - sheds);
    assert_eq!(s.errors, 0);
    assert_eq!(tier.in_flight(), 0, "all permits released");
}

/// The infer path through the tier is bitwise identical to calling the
/// service handle directly.
#[test]
fn tier_infer_matches_direct_service_bitwise() {
    let policy = ServerPolicy { shards: 2, ..ServerPolicy::default() };
    let (tier, metrics) = tier(policy, 8);
    let direct = ServiceHandle::from_software(software(8));
    for seed in 0..10usize {
        let img: Vec<f32> = (0..8).map(|i| ((seed * 5 + i) % 7) as f32 * 0.2 - 0.6).collect();
        let got = match tier.infer(tier.assign_shard(), img.clone(), None) {
            TierReply::Ok(v) => v,
            other => panic!("infer {seed}: {other:?}"),
        };
        let want = direct.infer_batch(vec![img]).expect("direct infer");
        let want = want.first().expect("one logit row");
        assert_eq!(bits(&got), bits(want), "infer {seed} diverged");
    }
    assert_eq!(metrics.snapshot().errors, 0);
}
