//! Full-stack integration tests: AOT artifacts → PJRT runtime → engine →
//! batcher → TCP server. Every test skips gracefully when `artifacts/`
//! has not been built (`make artifacts`).
//!
//! NOTE: PJRT state is process-global-ish (one CPU client per engine
//! thread), so all tests share one engine via OnceLock.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, OnceLock};

use pdpu::coordinator::{json, Metrics, Server, ServiceHandle};

fn artifacts_dir() -> &'static str {
    concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")
}

fn engine() -> Option<&'static ServiceHandle> {
    static ENGINE: OnceLock<Option<ServiceHandle>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            if !std::path::Path::new(artifacts_dir()).join("manifest.json").exists() {
                eprintln!("skipping integration tests: run `make artifacts` first");
                return None;
            }
            Some(ServiceHandle::start(artifacts_dir()).expect("engine start"))
        })
        .as_ref()
}

#[test]
fn model_info_matches_manifest() {
    let Some(e) = engine() else { return };
    let info = e.info();
    assert_eq!(info.batch, 32);
    assert_eq!(info.input_dim, 784);
    assert_eq!(info.classes, 10);
    assert_eq!((info.n_in, info.n_out, info.es), (13, 16, 2));
}

#[test]
fn infer_batch_produces_finite_logits() {
    let Some(e) = engine() else { return };
    let images: Vec<Vec<f32>> = (0..5).map(|i| vec![0.1 * i as f32; 784]).collect();
    let out = e.infer_batch(images).expect("infer");
    assert_eq!(out.len(), 5);
    for logits in &out {
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    // identical inputs → identical outputs (deterministic path)
    let a = e.infer_batch(vec![vec![0.25; 784]]).unwrap();
    let b = e.infer_batch(vec![vec![0.25; 784]]).unwrap();
    assert_eq!(a, b);
}

/// The AOT GEMM must agree with the *Rust* posit semantics: quantize
/// inputs to P(13,2), f32-accumulate, quantize the result to P(16,2).
/// This is the cross-layer equivalence at tensor level.
#[test]
fn gemm_matches_rust_posit_semantics() {
    use pdpu::posit::{Posit, PositFormat};
    let Some(e) = engine() else { return };
    let (m, k, n) = e.info().gemm_mkn;
    let mut rng = pdpu::testing::Rng::seeded(0x6E44);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    let c = e.gemm(a.clone(), b.clone()).expect("gemm");

    let p13 = PositFormat::p(13, 2);
    let p16 = PositFormat::p(16, 2);
    let qa: Vec<f32> = a.iter().map(|&v| Posit::from_f64(v as f64, p13).to_f64() as f32).collect();
    let qb: Vec<f32> = b.iter().map(|&v| Posit::from_f64(v as f64, p13).to_f64() as f32).collect();
    let mut exact_match = 0usize;
    let samples = 400usize;
    for s in 0..samples {
        let (i, j) = ((s * 7919) % m, (s * 104729) % n);
        let mut acc = 0f32;
        for kk in 0..k {
            acc += qa[i * k + kk] * qb[kk * n + j];
        }
        let want = Posit::from_f64(acc as f64, p16).to_f64() as f32;
        let got = c[i * n + j];
        let rel = ((got - want) / want.abs().max(1e-6)).abs();
        // tile-blocked f32 accumulation reassociates: allow ~P(16,2)-ulp
        assert!(rel < 3e-3, "c[{i},{j}] = {got}, want {want} (rel {rel})");
        if got == want {
            exact_match += 1;
        }
    }
    assert!(
        exact_match as f64 / samples as f64 > 0.8,
        "only {exact_match}/{samples} bit-identical with the Rust oracle"
    );
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let Some(e) = engine() else { return };
    let mut rng = pdpu::testing::Rng::seeded(0x7EA);
    // blob batch like dnn::dataset::mnist_like
    let data = pdpu::dnn::mnist_like(99, 32, 10);
    let images: Vec<Vec<f32>> = data.images.iter().map(|im| im.iter().map(|&v| v as f32).collect()).collect();
    let labels: Vec<u32> = data.labels.iter().map(|&l| l as u32).collect();
    let first = e.train_step(images.clone(), labels.clone()).expect("train");
    let mut last = first;
    for _ in 0..15 {
        last = e.train_step(images.clone(), labels.clone()).expect("train");
    }
    assert!(last < first * 0.9, "loss {first} → {last} (no learning on a fixed batch)");
    let _ = rng;
}

/// Software-backend serving: the batched PDPU engine behind the same
/// engine-thread / batcher / TCP stack, no artifacts or PJRT required —
/// this path always runs, even in a fresh offline checkout.
#[test]
fn software_backend_serves_without_artifacts() {
    use pdpu::pdpu::PdpuConfig;
    let e = ServiceHandle::start_software(
        PdpuConfig::paper_default(),
        vec![16, 10, 4],
        8,
        (3, 5, 2),
        0x50F7,
    )
    .unwrap();
    assert_eq!(e.info().input_dim, 16);
    assert_eq!(e.info().classes, 4);
    assert_eq!((e.info().n_in, e.info().n_out, e.info().es), (13, 16, 2));

    // inference: deterministic finite logits, batch-size independent
    let images: Vec<Vec<f32>> = (0..3).map(|i| vec![0.2 * (i + 1) as f32; 16]).collect();
    let out = e.infer_batch(images.clone()).expect("software infer");
    assert_eq!(out.len(), 3);
    assert!(out.iter().all(|l| l.len() == 4 && l.iter().all(|v| v.is_finite())));
    let solo = e.infer_batch(images[..1].to_vec()).expect("software infer");
    assert_eq!(solo[0], out[0]);

    // gemm serves through the batched engine
    let (m, k, n) = e.info().gemm_mkn;
    let c = e.gemm(vec![1.0; m * k], vec![0.5; k * n]).expect("software gemm");
    assert_eq!(c.len(), m * n);
    assert!((c[0] - k as f32 * 0.5).abs() < 1e-2, "c[0] = {}", c[0]);

    // training is served by the software backend too: posit SGD through
    // the batched engine, same wire op as the PJRT train artifact
    let images: Vec<Vec<f32>> = (0..8)
        .map(|i| (0..16).map(|p| if p % 4 == i % 4 { 1.0 } else { 0.1 }).collect())
        .collect();
    let labels: Vec<u32> = (0..8).map(|i| (i % 4) as u32).collect();
    let first = e.train_step(images.clone(), labels.clone()).expect("software train");
    let mut last = first;
    for _ in 0..14 {
        last = e.train_step(images.clone(), labels.clone()).expect("software train");
    }
    assert!(last < first, "software SGD did not learn a fixed batch: {first} → {last}");
    // bad requests still error per call
    let err = e.train_step(vec![vec![0.0; 16]], vec![9]).unwrap_err();
    assert!(err.contains("out of range"), "{err}");

    // full TCP round trip on the software backend
    let metrics = Arc::new(Metrics::new());
    let server = Server::start("127.0.0.1:0", e.clone(), metrics).expect("server");
    let stream = TcpStream::connect(server.addr).expect("connect");
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(json::parse(&line).unwrap().get("pong"), Some(&json::Json::Bool(true)));
    let img: Vec<f64> = (0..16).map(|p| p as f64 / 16.0).collect();
    let req = json::Json::obj(vec![
        ("op", json::Json::Str("infer".into())),
        ("image", json::Json::arr_f64(&img)),
    ]);
    writer.write_all((req.to_string() + "\n").as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.get("ok"), Some(&json::Json::Bool(true)), "{line}");
    assert_eq!(v.get("logits").unwrap().as_arr().unwrap().len(), 4);

    // gemm over the wire: routed through the gemm batcher + fusion path,
    // and identical to calling the engine handle directly
    let ga: Vec<f64> = (0..m * k).map(|i| (i as f64) * 0.125 - 0.5).collect();
    let gb: Vec<f64> = (0..k * n).map(|i| 1.0 - (i as f64) * 0.0625).collect();
    let req = json::Json::obj(vec![
        ("op", json::Json::Str("gemm".into())),
        ("a", json::Json::arr_f64(&ga)),
        ("b", json::Json::arr_f64(&gb)),
    ]);
    writer.write_all((req.to_string() + "\n").as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert_eq!(v.get("ok"), Some(&json::Json::Bool(true)), "{line}");
    let c_wire = v.get("c").unwrap().as_f64_vec().unwrap();
    assert_eq!(c_wire.len(), m * n);
    let direct = e
        .gemm(
            ga.iter().map(|&v| v as f32).collect(),
            gb.iter().map(|&v| v as f32).collect(),
        )
        .expect("direct gemm");
    for (i, (&w, &d)) in c_wire.iter().zip(&direct).enumerate() {
        assert_eq!(w as f32, d, "c[{i}] over the wire diverged");
    }

    // gemm shape errors surface per request
    let bad = json::Json::obj(vec![
        ("op", json::Json::Str("gemm".into())),
        ("a", json::Json::arr_f64(&[1.0])),
        ("b", json::Json::arr_f64(&gb)),
    ]);
    writer.write_all((bad.to_string() + "\n").as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("A must be"), "{line}");

    // stats now carry the fusion counters
    writer.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert!(v.get("gemm_requests").unwrap().as_f64().unwrap() >= 1.0, "{line}");
    assert!(v.get("fused_launches").unwrap().as_f64().unwrap() >= 1.0, "{line}");
    e.shutdown();
}

#[test]
fn tcp_server_roundtrip_and_batching() {
    let Some(e) = engine() else { return };
    let metrics = Arc::new(Metrics::new());
    let server = Server::start("127.0.0.1:0", e.clone(), metrics.clone()).expect("server");
    let addr = server.addr;

    // concurrent clients
    let mut handles = Vec::new();
    for t in 0..6 {
        handles.push(std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).expect("connect");
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);

            // ping
            writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let v = json::parse(&line).unwrap();
            assert_eq!(v.get("pong"), Some(&json::Json::Bool(true)));

            // a few inferences
            for i in 0..4 {
                let img: Vec<f64> = (0..784).map(|p| ((p + i + t) % 7) as f64 / 7.0).collect();
                let req = json::Json::obj(vec![
                    ("op", json::Json::Str("infer".into())),
                    ("image", json::Json::arr_f64(&img)),
                ]);
                writer.write_all((req.to_string() + "\n").as_bytes()).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let v = json::parse(&line).unwrap();
                assert_eq!(v.get("ok"), Some(&json::Json::Bool(true)), "{line}");
                assert_eq!(v.get("logits").unwrap().as_arr().unwrap().len(), 10);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    // error paths
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    for (req, frag) in [
        ("{\"op\":\"bogus\"}", "unknown op"),
        ("not json", "bad json"),
        ("{\"op\":\"infer\",\"image\":[1,2,3]}", "784"),
    ] {
        writer.write_all((req.to_string() + "\n").as_bytes()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains(frag), "req {req} → {line}");
    }

    // stats reflect the traffic
    writer.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let v = json::parse(&line).unwrap();
    assert!(v.get("responses").unwrap().as_f64().unwrap() >= 24.0, "{line}");
}
